"""Serving substrate: paged KV cache + continuous batching engine."""
from .engine import Request, ServeEngine  # noqa: F401
from .kv_cache import OutOfPages, PageAllocator, PagedKVCache  # noqa: F401
