"""Continuous-batching serving engine.

Fixed-capacity slot model: every engine step decodes one token for each
occupied slot (prompt tokens are teacher-forced through the same path —
"prefill-as-decode"), new requests are admitted into free slots between
steps, and completions are signalled by the paper's writeback convention:
each request owns a control descriptor in a :mod:`repro.runtime` channel
ring whose first-8-bytes all-ones flag the scheduler polls (§II-D; no
interrupts on TPU — DESIGN.md §2). All descriptor work in the serve path
goes through the runtime — the engine never calls ``execute_*`` directly
(DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import DecodeState, decode_step
from repro.models.transformer import init_decode_caches
from repro.obs.counters import PerfCounters, namespaced
from repro.obs.metrics import Histogram
from repro.obs.trace import Tracer, monotonic
from repro.runtime import ChannelConfig, DMARuntime
from repro.runtime.instrumentation import PerfProbe
from repro.runtime.submit import SubmitRequest, Ticket, reject_legacy_submit


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # Global KV page ids the request reads (sharded serving: the router
    # admits the request to the shard owning them — DESIGN.md §6). The
    # sharded migration path may rewrite these to the post-migration ids.
    kv_pages: Optional[List[int]] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    prompt_cursor: int = 0

    @property
    def busy(self) -> bool:
        return self.request is not None


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, capacity: int = 4,
                 max_len: int = 128, greedy: bool = True,
                 runtime: Optional[DMARuntime] = None,
                 completion_ring: int = 256):
        self.params, self.cfg = params, cfg
        self.capacity, self.max_len = capacity, max_len
        self.greedy = greedy
        self.queue: deque[Request] = deque()
        self.slots = [_Slot() for _ in range(capacity)]
        self.completed: Dict[int, Request] = {}
        # Completion channel: one control descriptor per request, living in
        # a submission ring; the step loop performs the §II-D writeback on
        # finish and poll_completed observes it through the ring.
        self.runtime = runtime or DMARuntime(
            [ChannelConfig(name="completion", tier="control",
                           ring_capacity=completion_ring)])
        self._completion_channel = "completion"
        ch = self.runtime.channels.get(self._completion_channel)
        if ch is None or ch.cfg.tier != "control":
            raise ValueError(
                "runtime must provide a control-tier channel named "
                f"'{self._completion_channel}' for request completions")
        self._tickets: Dict[int, int] = {}        # uid -> ring ticket
        self._ticket_uid: Dict[int, int] = {}     # ring ticket -> uid
        self._delivered: Dict[int, Request] = {}  # completion-event'd uids
        self._completed_at: Dict[int, int] = {}   # uid -> step of writeback
        self._submitted_at: Dict[int, int] = {}   # uid -> step of submit
        # End-to-end request latency (submit -> §II-D writeback) in decode
        # steps: deterministic under a fixed seed, so its p50/p99 are gated
        # per serve cell (schema v5). Small-integer domain -> the width-1
        # linear buckets make the percentiles exact (DESIGN.md §8).
        self.request_latency = Histogram()
        caches = init_decode_caches(cfg, capacity, max_len)
        self.state = DecodeState(
            caches, jnp.zeros((capacity,), jnp.int32))
        self._step_fn = jax.jit(
            lambda p, t, s: decode_step(p, t, s, cfg))
        self.steps = 0
        self.probe: Optional[PerfProbe] = None
        self.tracer: Optional[Tracer] = None
        self.track = "serve"
        self.step_seconds = 0.0
        self.active_slot_steps = 0
        self.admission_stalls = 0          # steps with queued work, no slot
        self.poll_latency_steps_sum = 0    # writeback -> poll observation
        self.poll_latency_n = 0

    # -- instrumentation ---------------------------------------------------------
    def attach_probe(self, probe: Optional[PerfProbe]) -> None:
        """Attach a perf counter sink to this engine AND its runtime."""
        self.probe = probe
        self.runtime.attach_probe(probe)

    def attach_tracer(self, tracer: Optional[Tracer], *,
                      track: str = "serve", track_prefix: str = "") -> None:
        """Attach a lifecycle tracer to this engine AND its runtime.

        Request lifecycles render as async spans on ``track``; the
        runtime's channel/completion/translation tracks get
        ``track_prefix`` (the sharded frontend passes ``shard{i}/``).
        """
        self.tracer = tracer
        self.track = track
        self.runtime.attach_tracer(tracer, track_prefix=track_prefix)

    def perf_counters(self) -> PerfCounters:
        """Engine-side counters under the unified ``serve.*`` namespace.

        Canonical keys are ``serve.<field>`` plus a nested ``translation``
        block (itself ``translation.*``-namespaced); the old bare-key
        aliases were removed one release after 0.4 (DESIGN.md §9).
        """
        depths = self.runtime.speculation_depths()
        raw = {
            "steps": self.steps,
            "step_seconds": self.step_seconds,
            "active_slot_steps": self.active_slot_steps,
            "mean_active_slots":
                self.active_slot_steps / self.steps if self.steps else 0.0,
            "completed": len(self.completed),
            "admission_stalls": self.admission_stalls,
            "admission_stall_rate":
                self.admission_stalls / self.steps if self.steps else 0.0,
            "completion_poll_latency_steps":
                (self.poll_latency_steps_sum / self.poll_latency_n
                 if self.poll_latency_n else 0.0),
            # Tail latency (ROADMAP: continuous batching under open-loop
            # traffic needs p50/p99, not means). Steps are scheduling
            # outcomes — deterministic under a fixed seed — so these gate.
            "request_latency_steps_p50": self.request_latency.percentile(50),
            "request_latency_steps_p99": self.request_latency.percentile(99),
            "request_latency_steps": self.request_latency.snapshot(),
            # Live §II-C speculation depth of the runtime under this engine
            # (mean over channels; a single-policy runtime reports that
            # policy's current decision).
            "speculation_depth":
                float(np.mean(list(depths.values()))) if depths else 0.0,
        }
        # Chain-lowering JIT counters of the runtime under this engine
        # (DESIGN.md §7): artifact hit/miss/evict + plan-memo traffic.
        return namespaced(
            raw, "serve",
            extra={"translation": self.runtime.translation_stats()})

    # -- API -------------------------------------------------------------------
    def submit(self, req) -> Optional[Ticket]:
        """Admit a request for continuous batching.

        The unified form takes a :class:`~repro.runtime.SubmitRequest`
        whose ``request`` field is the serve :class:`Request` (``transform``
        / ``priority`` / ``on_complete`` ride along) and returns the
        completion-descriptor :class:`~repro.runtime.Ticket` with ``uid``
        set. The legacy positional-``Request`` form was removed one
        release after 0.4 and raises ``TypeError``.
        """
        if not isinstance(req, SubmitRequest):
            reject_legacy_submit("ServeEngine.submit", req)
        if req.request is None:
            raise ValueError(
                "ServeEngine.submit needs SubmitRequest.request set to "
                "a serve Request")
        return self._admit_request(req.request,
                                   on_complete=req.on_complete)

    def _admit_request(self, req: Request, on_complete=None) -> Ticket:
        res = self.runtime.submit_control(
            payload=req.uid, channel=self._completion_channel,
            on_complete=on_complete)
        self._tickets[req.uid] = res.tickets[-1]
        self._ticket_uid[res.tickets[-1]] = req.uid
        self._submitted_at[req.uid] = self.steps
        self.queue.append(req)
        tr = self.tracer
        if tr is not None and tr.sampled(req.uid):
            # One async span per request lifetime, correlated by uid; the
            # matching "e" fires at the §II-D writeback in step().
            tr.async_begin("request", self.track, id=req.uid,
                           ticket=res.tickets[-1], uid=req.uid)
            tr.instant("request.submit", self.track, uid=req.uid,
                       ticket=res.tickets[-1])
        return dataclasses.replace(res, uid=req.uid)

    def poll_completed(self) -> List[Request]:
        """Scheduler-side completion polling via descriptor writeback flags.

        Drains the runtime (retiring written-back ring entries into the
        completion queue) and returns every request whose writeback has
        been observed — either as a retired completion event or by
        scanning live ring slots, so a finished request is visible even
        while in-order retirement is blocked behind an older one.
        """
        self.runtime.drain_all()
        done_tickets = [rec.ticket for rec in self.runtime.poll()]
        ring = self.runtime.channels[self._completion_channel].ring
        done_tickets.extend(ring.live_done_tickets())
        for ticket in done_tickets:
            uid = self._ticket_uid.get(ticket)
            if uid is not None and uid in self.completed:
                if uid not in self._delivered:
                    # Poll latency: decode steps between the §II-D
                    # writeback and the scheduler observing it here.
                    latency = self.steps - self._completed_at.get(
                        uid, self.steps)
                    self.poll_latency_steps_sum += latency
                    self.poll_latency_n += 1
                    if self.probe is not None:
                        self.probe.on_serve_completion(
                            latency_steps=latency)
                    tr = self.tracer
                    if tr is not None and tr.sampled(uid):
                        tr.instant("delivered", self.track, uid=uid,
                                   poll_latency_steps=latency)
                self._delivered[uid] = self.completed[uid]
        return list(self._delivered.values())

    def run(self, max_steps: int = 1000) -> Dict[int, Request]:
        while (self.queue or any(s.busy for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.completed

    # -- engine internals --------------------------------------------------------
    def _reset_slot_caches(self, b: int) -> None:
        def reset(leaf):
            if not hasattr(leaf, "ndim"):
                return leaf
            return leaf
        # Position tags are authoritative: clearing them invalidates the ring.
        caches = self.state.caches

        def clear(x, batch_axis):
            idx = [slice(None)] * x.ndim
            idx[batch_axis] = b
            return x.at[tuple(idx)].set(-1 if x.dtype == jnp.int32 else 0)

        def walk(tree):
            import repro.models.attention as A
            import repro.models.mamba as M
            if isinstance(tree, A.KVCacheView):
                stacked = tree.k.ndim == 5      # (periods, B, ...)
                ax = 1 if stacked else 0
                return A.KVCacheView(clear(tree.k, ax), clear(tree.v, ax),
                                     clear(tree.kv_pos, ax))
            if isinstance(tree, M.MambaCache):
                stacked = tree.state.ndim == 5
                ax = 1 if stacked else 0
                return M.MambaCache(clear(tree.conv, ax),
                                    clear(tree.state, ax))
            if isinstance(tree, dict):
                return {k: walk(v) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                return type(tree)(walk(v) for v in tree)
            return tree

        new_caches = walk(caches)
        cur = self.state.cur_pos.at[b].set(0)
        self.state = DecodeState(new_caches, cur)

    def _admit(self) -> None:
        for b, slot in enumerate(self.slots):
            if not slot.busy and self.queue:
                slot.request = self.queue.popleft()
                slot.prompt_cursor = 0
                self._reset_slot_caches(b)
        if self.queue:
            # Admission stall: requests are waiting but every slot is busy
            # — the continuous-batching pressure signal the perf sweep
            # gates (DESIGN.md §5).
            self.admission_stalls += 1
            if self.probe is not None:
                self.probe.on_admission_stall()

    def step(self) -> None:
        t0 = monotonic()
        self._admit()
        active = np.array([s.busy for s in self.slots])
        if not active.any():
            return
        tokens = np.zeros((self.capacity,), np.int32)
        for b, slot in enumerate(self.slots):
            if not slot.busy:
                continue
            r = slot.request
            if slot.prompt_cursor < len(r.prompt):
                tokens[b] = r.prompt[slot.prompt_cursor]
            else:
                tokens[b] = r.output[-1] if r.output else 0

        logits, new_state = self._step_fn(self.params,
                                          jnp.asarray(tokens), self.state)
        sampled = np.asarray(jnp.argmax(logits, axis=-1))

        # Advance only active slots (inactive ring writes are invalidated on
        # admit via tag reset).
        cur = np.asarray(new_state.cur_pos)
        cur = np.where(active, cur, np.asarray(self.state.cur_pos))
        self.state = DecodeState(new_state.caches,
                                 jnp.asarray(cur, jnp.int32))

        for b, slot in enumerate(self.slots):
            if not slot.busy:
                continue
            r = slot.request
            if slot.prompt_cursor < len(r.prompt):
                # Consumed one prompt token; the step that consumes the LAST
                # prompt token emits the first generated token.
                slot.prompt_cursor += 1
                if slot.prompt_cursor < len(r.prompt):
                    continue
            tok = int(sampled[b])
            r.output.append(tok)
            finished = (len(r.output) >= r.max_new_tokens
                        or (r.eos_id is not None and tok == r.eos_id)
                        or int(cur[b]) >= self.max_len - 1)
            if finished:
                self.completed[r.uid] = r
                self._completed_at[r.uid] = self.steps + 1  # post-step index
                # §II-D completion writeback: first 8 bytes -> all ones,
                # applied to the request's ring slot through the runtime.
                self.runtime.complete(self._tickets[r.uid])
                latency = self.steps + 1 - self._submitted_at.get(r.uid, 0)
                self.request_latency.record(latency)
                if self.probe is not None:
                    self.probe.on_request_latency(latency)
                tr = self.tracer
                if tr is not None and tr.sampled(r.uid):
                    tr.instant("writeback", self.track, uid=r.uid,
                               ticket=self._tickets[r.uid])
                    tr.async_end("request", self.track, id=r.uid,
                                 latency_steps=latency)
                slot.request = None
        self.steps += 1
        dt = monotonic() - t0
        n_active = int(active.sum())
        self.step_seconds += dt
        self.active_slot_steps += n_active
        if self.probe is not None:
            self.probe.on_serve_step(n_active, dt)
        tr = self.tracer
        if tr is not None and tr.sampled(self.steps - 1):
            tr.complete("serve.step", self.track, t0 * 1e6, dt * 1e6,
                        step=self.steps - 1, active_slots=n_active)
