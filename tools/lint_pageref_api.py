#!/usr/bin/env python
"""PageRef lint: no new internal bare-int page-id call sites (DESIGN.md §11).

The virtual-addressing redesign made every page-holding surface —
``ShardedKVPool`` alloc/free/move/defragment/flip, ``PagedKVCache``
moves, ``Request.kv_pages`` — traffic in opaque :class:`PageRef`
handles. Bare ``int`` page ids still *work* for one release through the
``as_pageref`` DeprecationWarning shim (mirroring the PR 8
``SubmitRequest`` bridge), but first-party code must not lean on the
shim: handles come from the pool (``alloc_on``/``refs``/``defragment``/
``flip_ownership``), never from integer literals the caller made up.

A call site is flagged when a *pages-position* argument is an integer
literal the author typed:

* a pure int-literal list/tuple (``[3, 4, 5]``) passed to a page-list
  API (``move_pages``, ``release``, ``page_rows``, ``flip_ownership``,
  ``ensure_resident``, ``defragment``) or to ``kv_pages=``;
* a bare int-literal scalar as ``write_page``'s first argument.

Variables, comprehensions, slices and ``pool.refs(...)`` calls all pass:
the lint keys on literal shape, not on proving provenance — exactly like
``lint_submit_api.py``. ``tests/`` is deliberately NOT scanned: the shim
contract itself (bare ints warn, then keep working) is pinned by tests
that must type bare ints. ``ShardedKVPool.defragment`` takes a page
list while ``PagedKVCache.defragment`` takes a sequence-slot int, so
only the list-literal rule applies to ``defragment`` — ``.defragment(0)``
is a slot, not a page id.

Usage: python tools/lint_pageref_api.py [--root DIR]
Exit status 1 if any bare-int page-id call site is found (CI lint job).
"""
from __future__ import annotations

import argparse
import io
import pathlib
import re
import sys
import tokenize

SCAN_DIRS = ("src/repro", "benchmarks", "examples")
#: APIs whose pages argument is a list of handles.
PAGE_LIST_APIS = ("move_pages", "release", "page_rows", "flip_ownership",
                  "ensure_resident", "defragment")
#: A list/tuple literal whose elements are ALL bare int literals. The
#: lookbehind rejects indexing brackets (``flipped[0]``, ``pages[1]``).
INT_LIST = re.compile(
    r"(?<![\w\])])[\[(]\s*\d+\s*(?:,\s*\d+\s*)*,?\s*[\])]")
CALL = re.compile(
    r"\.(" + "|".join(PAGE_LIST_APIS) + r")\(|(?<![\w.])kv_pages\s*=")
WRITE_PAGE = re.compile(r"\.write_page\(\s*(\d)")


def _call_window(text: str, open_paren: int) -> str:
    """Return the balanced ``(...)`` argument window starting at open_paren."""
    depth = 0
    for i in range(open_paren, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return text[open_paren + 1:i]
    return text[open_paren + 1:]


def _kwarg_window(text: str, start: int) -> str:
    """The ``kv_pages=`` value expression up to the enclosing ``,`` / ``)``."""
    depth = 0
    for i in range(start, len(text)):
        c = text[i]
        if c in "([{":
            depth += 1
        elif c in ")]}":
            if depth == 0:
                return text[start:i]
            depth -= 1
        elif c == "," and depth == 0:
            return text[start:i]
    return text[start:]


def _blank_strings_and_comments(text: str) -> str:
    """Replace string/comment token contents with spaces (same offsets), so
    docstrings showing the deprecated bare-int form don't trip the scan."""
    out = list(text)
    starts = [0]                       # starts[row-1] = offset of 1-based row
    for ln in text.splitlines(keepends=True):
        starts.append(starts[-1] + len(ln))
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except tokenize.TokenError:
        return text
    for tok in tokens:
        if tok.type in (tokenize.STRING, tokenize.COMMENT):
            a = starts[tok.start[0] - 1] + tok.start[1]
            b = starts[tok.end[0] - 1] + tok.end[1]
            for i in range(a, min(b, len(out))):
                if out[i] != "\n":
                    out[i] = " "
    return "".join(out)


def lint_file(path: pathlib.Path) -> list:
    text = _blank_strings_and_comments(path.read_text())
    findings = []
    for m in CALL.finditer(text):
        line = text.count("\n", 0, m.start()) + 1
        if m.group(0).startswith("kv_pages"):
            window = _kwarg_window(text, m.end())
            api = "kv_pages="
        else:
            window = _call_window(text, m.end() - 1)
            api = f".{m.group(1)}(...)"
        hit = INT_LIST.search(window)
        if hit:
            findings.append((line, f"{api} takes PageRef handles; "
                                   f"{hit.group(0)!r} is a bare int-literal "
                                   "page list — mint handles via the pool "
                                   "(alloc_on/refs/defragment/"
                                   "flip_ownership)"))
    for m in WRITE_PAGE.finditer(text):
        line = text.count("\n", 0, m.start()) + 1
        findings.append((line, ".write_page(...) takes a PageRef handle; "
                               "a bare int-literal page id leans on the "
                               "one-release deprecation shim"))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=pathlib.Path,
                    default=pathlib.Path(__file__).resolve().parent.parent)
    args = ap.parse_args(argv)

    failures = 0
    for rel in SCAN_DIRS:
        base = args.root / rel
        for path in sorted(base.rglob("*.py")):
            for line, msg in lint_file(path):
                print(f"{path.relative_to(args.root)}:{line}: "
                      f"bare-int page-id call site: {msg}")
                failures += 1
    if failures:
        print(f"\n{failures} bare-int page-id call site(s); first-party "
              "code must hold PageRef handles (DESIGN.md §11) — the int "
              "shim exists for out-of-tree callers, for one release.",
              file=sys.stderr)
        return 1
    print("pageref-api lint: all first-party call sites hold PageRef "
          "handles")
    return 0


if __name__ == "__main__":
    sys.exit(main())
