#!/usr/bin/env python
"""Legacy-submit lint: the removed keyword forms must not come back.

The unified submit contract (DESIGN.md §9) routes every submission —
``Channel.submit``, ``DMARuntime.submit``, ``ServeEngine.submit``,
``ShardedServeEngine.submit`` — through one ``SubmitRequest`` value. The
legacy keyword forms shipped behind deprecation shims for one release
after 0.4 and were then removed; every layer now raises ``TypeError``
on a non-``SubmitRequest`` first argument. This lint keeps the removal
honest: any resurrected legacy call site — or a reintroduction of the
shim machinery itself (``warn_legacy_submit``) — fails CI.

A call site is flagged when its first argument is not a
``SubmitRequest(...)`` literal AND the call window shows a legacy shape:

* a legacy chain-submit keyword (``src_pool=``, ``dst_pool=``, ``tier=``,
  ``on_complete=``, ``run_coalescer=``) outside a ``SubmitRequest``
  constructor, or
* a bare serve ``Request(...)`` as the first argument.

Calls that forward an existing ``SubmitRequest`` variable (for example the
scheduler handing a request down to a channel with extra positional
arguments) are fine — the lint keys on legacy *shape*, not on requiring a
literal. ``tests/`` is scanned too now that the shims are gone: the old
shim-pinning tests were rewritten against the TypeError contract, so any
legacy form in tests is a regression, not a pin.

Usage: python tools/lint_submit_api.py [--root DIR]
Exit status 1 if any legacy call site is found (the CI lint job's gate).
"""
from __future__ import annotations

import argparse
import io
import pathlib
import re
import sys
import tokenize

SCAN_DIRS = ("src/repro", "benchmarks", "examples", "tests")
LEGACY_KWARGS = ("src_pool=", "dst_pool=", "tier=", "on_complete=",
                 "run_coalescer=")
#: Identifiers of the removed shim machinery; any appearance in scanned
#: code (strings/comments excluded) means the one-release shims grew back.
BANNED_IDENTIFIERS = ("warn_legacy_submit", "extra_aliases")
CALL = re.compile(r"\.submit\(")


def _call_window(text: str, open_paren: int) -> str:
    """Return the balanced ``(...)`` argument window starting at open_paren."""
    depth = 0
    for i in range(open_paren, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return text[open_paren + 1:i]
    return text[open_paren + 1:]


def _strip_submit_request_args(window: str) -> str:
    """Drop every ``SubmitRequest(...)`` literal (its kwargs are the new
    contract, not legacy usage) so the legacy-keyword scan only sees
    arguments passed directly to ``.submit`` itself."""
    out = window
    while True:
        m = re.search(r"SubmitRequest\s*\(", out)
        if m is None:
            return out
        inner = _call_window(out, m.end() - 1)
        out = out[:m.start()] + out[m.end() + len(inner) + 1:]


def _blank_strings_and_comments(text: str) -> str:
    """Replace string/comment token contents with spaces (same offsets), so
    docstrings describing the legacy forms don't trip the scan."""
    out = list(text)
    starts = [0]                       # starts[row-1] = offset of 1-based row
    for ln in text.splitlines(keepends=True):
        starts.append(starts[-1] + len(ln))
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except tokenize.TokenError:
        return text
    for tok in tokens:
        if tok.type in (tokenize.STRING, tokenize.COMMENT):
            a = starts[tok.start[0] - 1] + tok.start[1]
            b = starts[tok.end[0] - 1] + tok.end[1]
            for i in range(a, min(b, len(out))):
                if out[i] != "\n":
                    out[i] = " "
    return "".join(out)


def lint_file(path: pathlib.Path) -> list:
    text = _blank_strings_and_comments(path.read_text())
    findings = []
    for ident in BANNED_IDENTIFIERS:
        for m in re.finditer(rf"\b{ident}\b", text):
            line = text.count("\n", 0, m.start()) + 1
            findings.append((line, f"removed shim identifier {ident!r} — "
                                   "the legacy submit shims are gone for "
                                   "good"))
    for m in CALL.finditer(text):
        window = _call_window(text, m.end() - 1)
        first_arg = window.lstrip()
        if re.match(r"SubmitRequest\s*\(", first_arg):
            continue
        line = text.count("\n", 0, m.start()) + 1
        if re.match(r"Request\s*\(", first_arg):
            findings.append((line, "bare serve Request(...) — wrap it in "
                                   "SubmitRequest(request=...)"))
            continue
        stripped = _strip_submit_request_args(window)
        hit = [kw for kw in LEGACY_KWARGS if kw in stripped.replace(" ", "")]
        if hit:
            findings.append((line, "legacy keyword form "
                                   f"({', '.join(hit)}) — pass a "
                                   "SubmitRequest instead"))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=pathlib.Path,
                    default=pathlib.Path(__file__).resolve().parent.parent)
    args = ap.parse_args(argv)

    failures = 0
    for rel in SCAN_DIRS:
        base = args.root / rel
        for path in sorted(base.rglob("*.py")):
            for line, msg in lint_file(path):
                print(f"{path.relative_to(args.root)}:{line}: "
                      f"legacy submit call site: {msg}")
                failures += 1
    if failures:
        print(f"\n{failures} legacy submit call site(s); first-party code "
              "must use the unified SubmitRequest contract (DESIGN.md §9).",
              file=sys.stderr)
        return 1
    print("submit-api lint: all first-party call sites use SubmitRequest")
    return 0


if __name__ == "__main__":
    sys.exit(main())
