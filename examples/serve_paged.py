"""End-to-end serving driver: continuous batching over a small LM with
paged-KV bookkeeping (descriptor chains as block tables).

Run: PYTHONPATH=src python examples/serve_paged.py [--arch qwen2.5-3b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.runtime import SubmitRequest
from repro.serve import PagedKVCache, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, capacity=args.capacity, max_len=96)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = list(rng.integers(1, cfg.vocab_size, rng.integers(4, 12)))
        engine.submit(SubmitRequest(request=Request(
            uid=uid, prompt=prompt, max_new_tokens=8)))
    done = engine.run(max_steps=2000)
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.output) for r in done.values())
    print(f"completed {len(done)}/{args.requests} requests in {dt:.1f}s "
          f"({engine.steps} engine steps, {total_tokens} tokens)")
    for uid, r in sorted(done.items()):
        print(f"  req {uid}: prompt[{len(r.prompt)}] -> {r.output}")
    assert len(engine.poll_completed()) == len(done), "writeback flags!"

    # Paged pool bookkeeping demo: per-sequence descriptor chains over
    # *virtual* page ids (DESIGN.md §11). Two interleaved sequences
    # fragment each other's layouts; remap-based defragmentation then
    # renumbers seq 0's pages onto a dense virtual run — page-table
    # writes only, not a single payload byte moved.
    pool = PagedKVCache(page=16, num_pages=64, max_seqs=args.capacity,
                        max_pages_per_seq=8, kv_heads=cfg.num_kv_heads or 1,
                        head_dim=cfg.head_dim_ or 8)
    pool.admit(0)
    pool.admit(1)
    zeros = np.zeros((pool.kv_heads, pool.head_dim))
    for _ in range(40):                 # interleaved growth fragments
        pool.append(0, zeros, zeros)
        pool.append(1, zeros, zeros)
    chain = pool.chain(0)
    before = pool.alloc.speculation_hit_rate(0)
    rate = pool.defragment(0)           # remap, no runtime needed
    refs = [pool.pageref(int(p)) for p in pool.tables[0] if p >= 0]
    print(f"paged cache: seq 0 holds {chain.num_descriptors} pages; "
          f"speculation hit rate {before:.0%} fragmented -> {rate:.0%} "
          "after remap defrag (0 bytes moved)")
    print(f"  PageRef handles: {refs}")


if __name__ == "__main__":
    main()
