"""End-to-end training driver: a reduced-config model for a few hundred
steps on CPU, with fault-tolerant checkpointing (kill/resume safe).

Run: PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import tempfile

from repro import optim
from repro.configs import get_config
from repro.data import DataConfig
from repro.train import Trainer, TrainConfig, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    n_params = cfg.param_counts()["total"]
    print(f"training {cfg.name}: ~{n_params/1e6:.1f}M params (analytic)")

    tcfg = TrainConfig(optimizer=optim.AdamWConfig(
        lr=3e-3, warmup_steps=20, total_steps=args.steps,
        schedule="cosine", weight_decay=0.01))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    run = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                        log_every=20, checkpoint_dir=ckpt_dir)

    def log(step, metrics):
        msg = " ".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in metrics.items())
        print(f"step {step:5d} {msg}")

    trainer = Trainer(cfg, tcfg, run, dcfg, log_fn=log)
    result = trainer.train()
    losses = result["losses"]
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"done at step {result['final_step']}: "
          f"loss {first:.3f} -> {last:.3f} "
          f"({len(result['stragglers'])} straggler steps flagged)")
    print(f"checkpoints in {ckpt_dir} — rerun with --ckpt-dir to resume")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
