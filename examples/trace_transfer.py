"""Observability walkthrough: trace one irregular transfer end to end.

Attaches a ``Tracer`` and ``PerfProbe`` to a two-channel runtime, submits
a seeded irregular descriptor chain, drains it, and exports

* ``transfer.trace.json``   — Chrome/Perfetto ``trace_event`` timeline
  (open at https://ui.perfetto.dev or chrome://tracing: one track per
  channel plus ``completion`` and ``translation``, submit/coalesce/drain/
  writeback spans, retire instants, completion.poll spans);
* ``transfer.metrics.jsonl`` — one JSON line per probe metric, including
  the log2-bucket latency histograms (DESIGN.md §8).

Everything is seeded, so two runs produce the same span structure.

Run: PYTHONPATH=src python examples/trace_transfer.py
"""
import json

import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_segments
from repro.obs import Tracer, write_chrome_trace, write_metrics_jsonl
from repro.runtime import SubmitRequest, default_runtime
from repro.runtime.instrumentation import PerfProbe

POOL, N_DESC, SEED = 1 << 14, 96, 0

# -- build: two serial channels, tracer sampling everything -----------------
tracer = Tracer(sample_rate=1.0, seed=SEED)
probe = PerfProbe()
rt = default_runtime(2, tier="serial", ring_capacity=N_DESC + 1, max_len=64)
rt.register_pool("src", jnp.arange(POOL, dtype=jnp.float32))
rt.register_pool("dst", jnp.zeros(POOL, jnp.float32))
rt.attach_probe(probe)
rt.attach_tracer(tracer)

# -- submit + drain one irregular (scatter/gather) chain --------------------
rng = np.random.default_rng(SEED)
chain = from_segments(rng.integers(0, POOL - 64, N_DESC),
                      rng.integers(0, POOL - 64, N_DESC),
                      rng.integers(1, 64, N_DESC))
# One SubmitRequest carries the whole contract: chain + pools + optional
# in-flight transform (e.g. transform="kv_int8") + priority + completion
# callback. on_complete registers an IRQ-style event on the chain's last
# ticket, so the poll below delivers a record (and the trace gains
# retire/delivered).
done = []
res = rt.submit(SubmitRequest(chain=chain, src_pool="src", dst_pool="dst",
                              on_complete=done.append))
rt.drain_until_idle()
events = rt.completion.poll()
print(f"drained {len(res.tickets)} tickets on channel {res.channel} "
      f"({len(rt.channels)} channels attached), "
      f"{len(events)} completion events polled")

# -- export -----------------------------------------------------------------
doc = write_chrome_trace("transfer.trace.json", tracer.events())
write_metrics_jsonl("transfer.metrics.jsonl", probe.metrics)
names = sorted({e.name for e in tracer.events()})
tracks = sorted({e.track for e in tracer.events()})
print(f"transfer.trace.json: {len(doc['traceEvents'])} events, "
      f"{len(tracks)} tracks (dropped={tracer.dropped})")
print("  tracks:", ", ".join(tracks))
print("  spans :", ", ".join(names))

launch = probe.metrics.get("launch_us")
if launch is not None:
    s = launch.snapshot()
    print(f"launch_us histogram: n={s['n']} p50={s['p50']} p99={s['p99']}")
print(json.dumps({"hint": "load transfer.trace.json at ui.perfetto.dev"}))
