"""Paper-reproduction demo: regenerate Fig 4 / Fig 5 / Table IV as CSV and
compare against the published claims.

Run: PYTHONPATH=src python examples/irregular_transfers.py
"""
from repro.core.simulator import (
    MEMORY_CONFIGS,
    SimConfig,
    ideal_utilization,
    simulate,
    table_iv,
)

SIZES = [64, 128, 256, 512, 1024, 4096]
CONFIGS = [SimConfig.base(), SimConfig.speculation(), SimConfig.scaled(),
           SimConfig.logicore_ip()]

print("=== Fig 4: steady-state bus utilization ===")
print("memory,config," + ",".join(f"{s}B" for s in SIZES) + ",ideal64B")
for mem, L in MEMORY_CONFIGS.items():
    for cfg in CONFIGS:
        us = [simulate(cfg, L, s).utilization for s in SIZES]
        print(f"{mem},{cfg.name}," + ",".join(f"{u:.3f}" for u in us)
              + f",{ideal_utilization(64):.3f}")

print("\n=== headline claims ===")
r = lambda c, L: simulate(c, L, 64).utilization
lc = {L: r(SimConfig.logicore_ip(), L) for L in (1, 13, 100)}
print(f"ideal  64B base/LogiCORE        : {r(SimConfig.base(),1)/lc[1]:.2f} (paper 2.5x)")
print(f"ddr3   64B base/LogiCORE        : {r(SimConfig.base(),13)/lc[13]:.2f} (paper 1.7x)")
print(f"ddr3   64B speculation/LogiCORE : {r(SimConfig.speculation(),13)/lc[13]:.2f} (paper 3.9x)")
print(f"deep   64B scaled/LogiCORE      : {r(SimConfig.scaled(),100)/lc[100]:.2f} (paper >=3.6x)")

print("\n=== Fig 5: speculation miss sensitivity (DDR3, 64B) ===")
for h in (0.0, 0.25, 0.5, 0.75, 1.0):
    res = simulate(SimConfig.speculation(), 13, 64, hit_rate=h)
    print(f"hit_rate={h:.2f}: util={res.utilization:.3f} "
          f"(x{res.utilization/lc[13]:.2f} vs LogiCORE), "
          f"wasted descriptor beats={res.wasted_beats}")

print("\n=== Table IV: latencies (cycles) ===")
t = table_iv()
for who in ("ours", "logicore"):
    row = t[who]
    paper = t["paper"][who]
    print(f"{who:9s} i-rf={row['i_rf']} (paper {paper['i_rf']})  "
          + "  ".join(f"rf-rb@L{L}={row['rf_rb'][L]:.0f} (paper {paper['rf_rb'][L]})"
                      for L in (1, 13, 100))
          + f"  r-w={row['r_w']}")
print(f"\nlaunch-latency improvement: "
      f"{(t['logicore']['i_rf']+t['logicore']['rf_rb'][13]) / (t['ours']['i_rf']+t['ours']['rf_rb'][13]):.2f}x "
      f"(paper abstract: 1.66x)")
