"""Quickstart: the paper's descriptor mechanism end to end.

1. Describe an irregular (2-D strided) transfer as a descriptor chain.
2. Plan a sequential layout -> the hardware speculator's hit rate becomes 1.0.
3. Execute with the JAX engine and the Pallas kernel; verify they agree.
4. Ask the cycle simulator what bus utilization this transfer pattern gets
   with and without speculative prefetching.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SimConfig,
    from_strided_2d,
    plan_sequential_layout,
    simulate,
    to_packed,
)
from repro.core.engine import execute_chain_host, execute_serial
from repro.kernels import chain_copy_op

# -- 1. An irregular transfer: gather a 16x64 tile out of a 64x256 image. ----
ROW, NROWS = 64, 16
chain = from_strided_2d(src_base=0, dst_base=0, row_len=ROW, num_rows=NROWS,
                        src_stride=256, dst_stride=ROW)
print(f"built a chain of {chain.num_descriptors} descriptors "
      f"({ROW} elements each)")
packed = to_packed(chain, elem_bytes=4)
print(f"packed descriptor table: {packed.nbytes} bytes "
      f"({packed.nbytes // len(packed)} B/descriptor — paper Listing 1)")

# -- 2. Sequential layout -> speculation hits by construction. ---------------
table, hit_rate = plan_sequential_layout(chain)
print(f"planned layout speculation hit rate: {hit_rate:.0%}")

# -- 3. Execute: host oracle == jitted serial engine == Pallas kernel. -------
rng = np.random.default_rng(0)
src = rng.standard_normal(64 * 256).astype(np.float32)
dst = np.zeros(NROWS * ROW, np.float32)
want, _ = execute_chain_host(chain, src, dst)
got, _ = execute_serial(chain, jnp.asarray(src), jnp.asarray(dst),
                        max_len=ROW)
np.testing.assert_array_equal(np.asarray(got), want)

# Row-pool form for the kernel (rows of 64 elements = fixed "bursts");
# element offsets become row ids in the (256/ROW)-rows-per-line pool.
from repro.core.descriptor import DescriptorArray

src_rows = jnp.asarray(src.reshape(256, ROW))
dst_rows = jnp.zeros((NROWS, ROW), jnp.float32)
row_ids = np.asarray(chain.src) // 256 * (256 // ROW)
row_chain = DescriptorArray.create(row_ids, np.arange(NROWS), np.ones(NROWS))
kout = chain_copy_op(row_chain, src_rows, dst_rows)
np.testing.assert_array_equal(np.asarray(kout).reshape(-1),
                              want.reshape(NROWS, ROW).reshape(-1))
print("host oracle == serial engine == Pallas chain_copy  [OK]")

# -- 4. What does the DMAC get out of this pattern? ---------------------------
n_bytes = ROW * 4
for cfg in (SimConfig.base(), SimConfig.speculation()):
    r = simulate(cfg, mem_latency=13, transfer_bytes=n_bytes,
                 hit_rate=hit_rate)
    print(f"{cfg.name:12s} @DDR3, {n_bytes}B rows: bus utilization "
          f"{r.utilization:.3f} (ideal {r.ideal:.3f})")
print("speculative prefetching closes the descriptor-fetch gap — the "
      "paper's core claim, reproduced.")
